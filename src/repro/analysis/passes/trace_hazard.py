"""Pass 1 — trace-hazard: host syncs and Python control flow under a trace.

Two rule families:

* Inside jit/scan/shard_map-reachable functions (per the module-local
  reachability approximation in :mod:`repro.analysis.jaxast`):

  - ``trace-hazard/host-sync``     ``.item()`` / ``.tolist()`` anywhere, and
    ``np.asarray`` / ``np.array`` on a value derived from a traced operand.
  - ``trace-hazard/host-cast``     ``int()``/``float()``/``bool()`` on a
    value derived from a traced operand (shape/static expressions exempt).
  - ``trace-hazard/python-control-flow``  ``if``/``while`` whose test
    depends on a traced operand (``is None`` / isinstance / string-compare
    guards exempt — those are static dispatch, not data-dependent flow).

* In every function of a ``serving/`` module, traced or not
  (``trace-hazard/serving-host-sync``): the serving hot path must stay
  dispatch-async, so any ``.item()``, ``np.asarray``-style conversion, or
  ``int(...)``/``float(...)`` wrapping a call result forces a device sync
  per batch and gets flagged.  Shape reads like ``int(x.shape[0])`` stay
  legal.  Findings here are expected to be either fixed or carried in
  ``analysis/baseline.json`` with a reason (e.g. checkpoint restore).

Traced-ness is a syntactic taint: positional parameters of a reachable
function seed the set, assignments whose right-hand side mentions a
tainted name extend it.  Keyword-only parameters are treated as static —
the repo's idiom is to partial-bind configuration kw-only and close over
it before jitting.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..engine import AnalysisContext, Finding
from ..jaxast import (FuncInfo, alias_map, collect_functions, contains_call,
                      jit_reachable, resolves_to)

R_SYNC = "trace-hazard/host-sync"
R_CAST = "trace-hazard/host-cast"
R_FLOW = "trace-hazard/python-control-flow"
R_SERVE = "trace-hazard/serving-host-sync"

NUMPY_HOST = {"numpy.asarray", "numpy.array", "numpy.ascontiguousarray"}
STATIC_CALLS = {"len", "isinstance", "type", "hasattr", "getattr",
                "jax.numpy.shape", "numpy.shape", "jax.numpy.ndim"}
SHAPE_ATTRS = {"shape", "ndim", "size", "dtype", "itemsize", "nbytes"}
HOST_METHODS = {"item", "tolist"}


def _is_static(node: ast.AST, tainted: set[str],
               aliases: dict[str, str]) -> bool:
    """True when evaluating ``node`` cannot touch a traced value."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        return node.id not in tainted
    if isinstance(node, ast.Attribute):
        if node.attr in SHAPE_ATTRS:
            return True          # shapes/dtypes are static under tracing
        return _is_static(node.value, tainted, aliases)
    if isinstance(node, ast.Subscript):
        return (_is_static(node.value, tainted, aliases)
                and _is_static(node.slice, tainted, aliases))
    if isinstance(node, ast.Call):
        # len() of a traced array is its (static) leading dim; isinstance
        # and friends never trace.  int(x.shape[0])-style casts of static
        # expressions stay static.  Anything else is assumed dynamic.
        if (isinstance(node.func, ast.Name)
                and node.func.id in ("int", "float", "bool")):
            return all(_is_static(a, tainted, aliases) for a in node.args)
        return resolves_to(node.func, aliases, STATIC_CALLS) is not None
    if isinstance(node, (ast.BinOp, ast.BoolOp, ast.Compare, ast.UnaryOp,
                         ast.IfExp, ast.Tuple, ast.List, ast.Set)):
        return all(_is_static(c, tainted, aliases)
                   for c in ast.iter_child_nodes(node)
                   if not isinstance(c, (ast.operator, ast.boolop,
                                         ast.cmpop, ast.unaryop,
                                         ast.expr_context)))
    return False


def _taint_set(fn: FuncInfo) -> set[str]:
    tainted = {p for p in fn.pos_params if p != "self"}
    # One forward sweep: an assignment whose RHS mentions taint taints its
    # targets, unless the RHS is a static (shape-like) expression.
    for node in ast.walk(fn.node):
        if isinstance(node, ast.Assign):
            value, targets = node.value, node.targets
        elif isinstance(node, ast.AugAssign):
            value, targets = node.value, [node.target]
        else:
            continue
        names = {n.id for n in ast.walk(value) if isinstance(n, ast.Name)}
        if not (names & tainted):
            continue
        if _is_static(value, tainted, {}):
            continue
        for t in targets:
            for leaf in ast.walk(t):
                if isinstance(leaf, ast.Name):
                    tainted.add(leaf.id)
    return tainted


def _exempt_test(test: ast.AST) -> bool:
    """Static-dispatch guards that look tainted but never trace."""
    if isinstance(test, ast.Compare):
        if any(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
            return True
        operands = [test.left, *test.comparators]
        if any(isinstance(o, ast.Constant) and isinstance(o.value, str)
               for o in operands):
            return True
    if isinstance(test, ast.Call):
        return True    # callable(..)/isinstance(..)-style predicate guards
    if isinstance(test, ast.BoolOp):
        return all(_exempt_test(v) for v in test.values)
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _exempt_test(test.operand)
    return False


def _scan_reachable(mod, fn: FuncInfo, aliases) -> Iterable[Finding]:
    if isinstance(fn.node, ast.Lambda):
        return
    tainted = _taint_set(fn)
    own_nested = {n for n in ast.walk(fn.node)
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                  and n is not fn.node}

    def walk(node):
        for child in ast.iter_child_nodes(node):
            if child in own_nested:
                continue          # nested defs are scanned as themselves
            yield child
            yield from walk(child)

    for node in walk(fn.node):
        if isinstance(node, ast.Call):
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in HOST_METHODS
                    and not node.args):
                yield Finding(mod.rel, node.lineno, R_SYNC, fn.qualname,
                              f".{node.func.attr}() forces a host sync "
                              "inside traced code")
            elif resolves_to(node.func, aliases, NUMPY_HOST):
                if any(not _is_static(a, tainted, aliases)
                       for a in node.args):
                    yield Finding(mod.rel, node.lineno, R_SYNC, fn.qualname,
                                  "numpy conversion of a traced value pulls "
                                  "it to host")
            elif (isinstance(node.func, ast.Name)
                  and node.func.id in ("int", "float", "bool")
                  and node.args
                  and not _is_static(node.args[0], tainted, aliases)):
                yield Finding(mod.rel, node.lineno, R_CAST, fn.qualname,
                              f"{node.func.id}() on a traced value forces "
                              "concretization")
        elif isinstance(node, (ast.If, ast.While)):
            test = node.test
            if (not _is_static(test, tainted, aliases)
                    and not _exempt_test(test)):
                kind = "if" if isinstance(node, ast.If) else "while"
                yield Finding(mod.rel, node.lineno, R_FLOW, fn.qualname,
                              f"python `{kind}` on a traced value — use "
                              "lax.cond/select/while_loop")


def _scan_serving(mod, fn: FuncInfo, aliases,
                  already: set) -> Iterable[Finding]:
    if isinstance(fn.node, ast.Lambda):
        return
    for node in ast.walk(fn.node):
        if not isinstance(node, ast.Call):
            continue
        key = (node.lineno, node.col_offset)
        if key in already:
            continue
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in HOST_METHODS and not node.args):
            already.add(key)
            yield Finding(mod.rel, node.lineno, R_SERVE, fn.qualname,
                          f".{node.func.attr}() syncs the serving loop "
                          "with the device")
        elif resolves_to(node.func, aliases, NUMPY_HOST):
            already.add(key)
            yield Finding(mod.rel, node.lineno, R_SERVE, fn.qualname,
                          "np conversion materializes device results in "
                          "the serving path")
        elif (isinstance(node.func, ast.Name)
              and node.func.id in ("int", "float", "bool")
              and node.args and contains_call(node.args[0])):
            already.add(key)
            yield Finding(mod.rel, node.lineno, R_SERVE, fn.qualname,
                          f"{node.func.id}(...) around a computed value "
                          "blocks on the device per call")


def run(ctx: AnalysisContext) -> Iterable[Finding]:
    out: list[Finding] = []
    for mod in ctx.modules:
        aliases = alias_map(mod.tree)
        reachable = jit_reachable(mod.tree, aliases)
        for fn in reachable.values():
            out.extend(_scan_reachable(mod, fn, aliases))
        if "serving/" in mod.rel or mod.rel.startswith("serving"):
            reach_lines = {f.line for f in out if f.path == mod.rel}
            already: set = set()
            for fn in collect_functions(mod.tree):
                for f in _scan_serving(mod, fn, aliases, already):
                    if f.line not in reach_lines:
                        out.append(f)
    return out
