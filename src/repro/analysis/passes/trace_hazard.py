"""Pass 1 — trace-hazard: host syncs and Python control flow under a trace.

Two rule families:

* Inside jit/scan/shard_map-reachable functions (per the module-local
  reachability approximation in :mod:`repro.analysis.jaxast`):

  - ``trace-hazard/host-sync``     ``.item()`` / ``.tolist()`` anywhere, and
    ``np.asarray`` / ``np.array`` on a value derived from a traced operand.
  - ``trace-hazard/host-cast``     ``int()``/``float()``/``bool()`` on a
    value derived from a traced operand (shape/static expressions exempt).
  - ``trace-hazard/python-control-flow``  ``if``/``while`` whose test
    depends on a traced operand (``is None`` / isinstance / string-compare
    guards exempt — those are static dispatch, not data-dependent flow).

* In every function of a ``serving/`` module, traced or not
  (``trace-hazard/serving-host-sync``): the serving hot path must stay
  dispatch-async, so any ``.item()``, ``np.asarray``-style conversion, or
  ``int(...)``/``float(...)`` wrapping a call result forces a device sync
  per batch and gets flagged.  Shape reads like ``int(x.shape[0])`` stay
  legal.  Findings here are expected to be either fixed or carried in
  ``analysis/baseline.json`` with a reason (e.g. checkpoint restore).

* Buffer donation on the streaming AOT programs (``serving/`` modules with
  a module-level ``STREAM_DONATION`` table):

  - ``trace-hazard/use-after-donate``  a symbol passed into a donated
    argnum of an AOT bucket program (``self._s_route[b](...)``, or a local
    alias of one) is read again before being rebound — its buffer was
    handed to XLA and deleted. Rebinding in the same assignment statement
    (``self.state, ... = prog(..., self.state, ...)``) is the sanctioned
    idiom.
  - ``trace-hazard/donation-drift``    the donation wiring disagrees with
    itself: a donating assignment site whose ``donate_argnums`` literal
    contradicts the module's ``STREAM_DONATION`` entry (or binds a program
    under a different key), a table key with no donating site, or — for
    the real ``serving/router_service.py`` — a table that disagrees with
    this pass's ``DONATED_ARGNUMS`` mirror (the PROTOCOL_ARITY pattern:
    both copies must change in the same PR).

Traced-ness is a syntactic taint: positional parameters of a reachable
function seed the set, assignments whose right-hand side mentions a
tainted name extend it.  Keyword-only parameters are treated as static —
the repo's idiom is to partial-bind configuration kw-only and close over
it before jitting.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..engine import AnalysisContext, Finding
from ..jaxast import (FuncInfo, alias_map, collect_functions, contains_call,
                      jit_reachable, resolves_to)

R_SYNC = "trace-hazard/host-sync"
R_CAST = "trace-hazard/host-cast"
R_FLOW = "trace-hazard/python-control-flow"
R_SERVE = "trace-hazard/serving-host-sync"
R_DONATE = "trace-hazard/use-after-donate"
R_DRIFT = "trace-hazard/donation-drift"

# Mirror of serving/router_service.py's STREAM_DONATION (the donated
# argnums of each AOT bucket program). Like the protocol-kernel pass's
# PROTOCOL_ARITY table, the lint carries its own copy of the wiring so a
# signature change that forgets one side is itself a finding
# (donation-drift) — keep both tables in the same PR.
DONATED_ARGNUMS = {
    "_s_route": (1, 2, 6, 8),
    "_s_route_pref": (1, 2, 6, 8),
    "_s_feedback": (0, 1, 5, 6),
    "_s_feedback_log": (0, 1, 5, 6, 7),
    "_s_resolve": (0, 4),
}
DONATION_TABLE = "STREAM_DONATION"

NUMPY_HOST = {"numpy.asarray", "numpy.array", "numpy.ascontiguousarray"}
STATIC_CALLS = {"len", "isinstance", "type", "hasattr", "getattr",
                "jax.numpy.shape", "numpy.shape", "jax.numpy.ndim"}
SHAPE_ATTRS = {"shape", "ndim", "size", "dtype", "itemsize", "nbytes"}
HOST_METHODS = {"item", "tolist"}


def _is_static(node: ast.AST, tainted: set[str],
               aliases: dict[str, str]) -> bool:
    """True when evaluating ``node`` cannot touch a traced value."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        return node.id not in tainted
    if isinstance(node, ast.Attribute):
        if node.attr in SHAPE_ATTRS:
            return True          # shapes/dtypes are static under tracing
        return _is_static(node.value, tainted, aliases)
    if isinstance(node, ast.Subscript):
        return (_is_static(node.value, tainted, aliases)
                and _is_static(node.slice, tainted, aliases))
    if isinstance(node, ast.Call):
        # len() of a traced array is its (static) leading dim; isinstance
        # and friends never trace.  int(x.shape[0])-style casts of static
        # expressions stay static.  Anything else is assumed dynamic.
        if (isinstance(node.func, ast.Name)
                and node.func.id in ("int", "float", "bool")):
            return all(_is_static(a, tainted, aliases) for a in node.args)
        return resolves_to(node.func, aliases, STATIC_CALLS) is not None
    if isinstance(node, (ast.BinOp, ast.BoolOp, ast.Compare, ast.UnaryOp,
                         ast.IfExp, ast.Tuple, ast.List, ast.Set)):
        return all(_is_static(c, tainted, aliases)
                   for c in ast.iter_child_nodes(node)
                   if not isinstance(c, (ast.operator, ast.boolop,
                                         ast.cmpop, ast.unaryop,
                                         ast.expr_context)))
    return False


def _taint_set(fn: FuncInfo) -> set[str]:
    tainted = {p for p in fn.pos_params if p != "self"}
    # One forward sweep: an assignment whose RHS mentions taint taints its
    # targets, unless the RHS is a static (shape-like) expression.
    for node in ast.walk(fn.node):
        if isinstance(node, ast.Assign):
            value, targets = node.value, node.targets
        elif isinstance(node, ast.AugAssign):
            value, targets = node.value, [node.target]
        else:
            continue
        names = {n.id for n in ast.walk(value) if isinstance(n, ast.Name)}
        if not (names & tainted):
            continue
        if _is_static(value, tainted, {}):
            continue
        for t in targets:
            for leaf in ast.walk(t):
                if isinstance(leaf, ast.Name):
                    tainted.add(leaf.id)
    return tainted


def _exempt_test(test: ast.AST) -> bool:
    """Static-dispatch guards that look tainted but never trace."""
    if isinstance(test, ast.Compare):
        if any(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
            return True
        operands = [test.left, *test.comparators]
        if any(isinstance(o, ast.Constant) and isinstance(o.value, str)
               for o in operands):
            return True
    if isinstance(test, ast.Call):
        return True    # callable(..)/isinstance(..)-style predicate guards
    if isinstance(test, ast.BoolOp):
        return all(_exempt_test(v) for v in test.values)
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _exempt_test(test.operand)
    return False


def _scan_reachable(mod, fn: FuncInfo, aliases) -> Iterable[Finding]:
    if isinstance(fn.node, ast.Lambda):
        return
    tainted = _taint_set(fn)
    own_nested = {n for n in ast.walk(fn.node)
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                  and n is not fn.node}

    def walk(node):
        for child in ast.iter_child_nodes(node):
            if child in own_nested:
                continue          # nested defs are scanned as themselves
            yield child
            yield from walk(child)

    for node in walk(fn.node):
        if isinstance(node, ast.Call):
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in HOST_METHODS
                    and not node.args):
                yield Finding(mod.rel, node.lineno, R_SYNC, fn.qualname,
                              f".{node.func.attr}() forces a host sync "
                              "inside traced code")
            elif resolves_to(node.func, aliases, NUMPY_HOST):
                if any(not _is_static(a, tainted, aliases)
                       for a in node.args):
                    yield Finding(mod.rel, node.lineno, R_SYNC, fn.qualname,
                                  "numpy conversion of a traced value pulls "
                                  "it to host")
            elif (isinstance(node.func, ast.Name)
                  and node.func.id in ("int", "float", "bool")
                  and node.args
                  and not _is_static(node.args[0], tainted, aliases)):
                yield Finding(mod.rel, node.lineno, R_CAST, fn.qualname,
                              f"{node.func.id}() on a traced value forces "
                              "concretization")
        elif isinstance(node, (ast.If, ast.While)):
            test = node.test
            if (not _is_static(test, tainted, aliases)
                    and not _exempt_test(test)):
                kind = "if" if isinstance(node, ast.If) else "while"
                yield Finding(mod.rel, node.lineno, R_FLOW, fn.qualname,
                              f"python `{kind}` on a traced value — use "
                              "lax.cond/select/while_loop")


def _scan_serving(mod, fn: FuncInfo, aliases,
                  already: set) -> Iterable[Finding]:
    if isinstance(fn.node, ast.Lambda):
        return
    for node in ast.walk(fn.node):
        if not isinstance(node, ast.Call):
            continue
        key = (node.lineno, node.col_offset)
        if key in already:
            continue
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in HOST_METHODS and not node.args):
            already.add(key)
            yield Finding(mod.rel, node.lineno, R_SERVE, fn.qualname,
                          f".{node.func.attr}() syncs the serving loop "
                          "with the device")
        elif resolves_to(node.func, aliases, NUMPY_HOST):
            already.add(key)
            yield Finding(mod.rel, node.lineno, R_SERVE, fn.qualname,
                          "np conversion materializes device results in "
                          "the serving path")
        elif (isinstance(node.func, ast.Name)
              and node.func.id in ("int", "float", "bool")
              and node.args and contains_call(node.args[0])):
            already.add(key)
            yield Finding(mod.rel, node.lineno, R_SERVE, fn.qualname,
                          f"{node.func.id}(...) around a computed value "
                          "blocks on the device per call")


def _int_tuple(node: ast.AST):
    """A literal tuple of ints (or a bare int) -> tuple; else None."""
    if isinstance(node, ast.Tuple) and node.elts and all(
            isinstance(e, ast.Constant) and isinstance(e.value, int)
            for e in node.elts):
        return tuple(e.value for e in node.elts)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    return None


def _donation_table(tree: ast.Module):
    """Parse a module-level ``STREAM_DONATION = {...}`` literal. Returns
    (table, key_lines); (None, {}) when the module declares none."""
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == DONATION_TABLE
                and isinstance(node.value, ast.Dict)):
            table, lines = {}, {}
            for k, v in zip(node.value.keys, node.value.values):
                tup = _int_tuple(v)
                if (isinstance(k, ast.Constant) and isinstance(k.value, str)
                        and tup is not None):
                    table[k.value] = tup
                    lines[k.value] = v.lineno
            return table, lines
    return None, {}


def _scan_donation_drift(mod, table, key_lines) -> Iterable[Finding]:
    """Donating assignment sites (``self.X = ... donate_argnums=...``) must
    agree with the module's STREAM_DONATION table, and every table key
    must have a site."""
    seen = set()
    for st in ast.walk(mod.tree):
        if not (isinstance(st, ast.Assign) and len(st.targets) == 1):
            continue
        tgt = st.targets[0]
        if not (isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self"):
            continue
        attr = tgt.attr
        for call in (n for n in ast.walk(st.value)
                     if isinstance(n, ast.Call)):
            kw = next((k for k in call.keywords
                       if k.arg == "donate_argnums"), None)
            if kw is None:
                continue
            if (isinstance(kw.value, ast.Subscript)
                    and isinstance(kw.value.value, ast.Name)
                    and kw.value.value.id == DONATION_TABLE
                    and isinstance(kw.value.slice, ast.Constant)):
                key = kw.value.slice.value
                seen.add(key)
                if table is None or key not in table:
                    yield Finding(mod.rel, kw.value.lineno, R_DRIFT, attr,
                                  f"donate_argnums reads "
                                  f"{DONATION_TABLE}[{key!r}] but the "
                                  f"table has no such key")
                elif key != attr:
                    yield Finding(mod.rel, kw.value.lineno, R_DRIFT, attr,
                                  f"program bound to self.{attr} donates "
                                  f"under table key {key!r} — keys name "
                                  f"the attribute they wire")
                continue
            tup = _int_tuple(kw.value)
            if tup is None:
                continue              # computed argnums: out of scope
            seen.add(attr)
            if table is None or attr not in table:
                yield Finding(mod.rel, kw.value.lineno, R_DRIFT, attr,
                              f"donating program self.{attr} has no "
                              f"{DONATION_TABLE} entry — declare the "
                              f"argnums in the module table")
            elif tup != table[attr]:
                yield Finding(mod.rel, kw.value.lineno, R_DRIFT, attr,
                              f"donate_argnums {tup} disagree with "
                              f"{DONATION_TABLE}[{attr!r}] = {table[attr]}")
    for key, line in key_lines.items():
        if key not in seen:
            yield Finding(mod.rel, line, R_DRIFT, DONATION_TABLE,
                          f"stale {DONATION_TABLE} key {key!r}: no "
                          f"donating assignment in this module uses it")


def _scan_mirror(mod, table, key_lines) -> Iterable[Finding]:
    """The real serving module's table must match this pass's mirror."""
    for key, val in table.items():
        want = DONATED_ARGNUMS.get(key)
        if want is None:
            yield Finding(mod.rel, key_lines[key], R_DRIFT, DONATION_TABLE,
                          f"{DONATION_TABLE} key {key!r} is not mirrored "
                          f"in repro-lint's DONATED_ARGNUMS — update "
                          f"analysis/passes/trace_hazard.py in the same "
                          f"PR")
        elif want != val:
            yield Finding(mod.rel, key_lines[key], R_DRIFT, DONATION_TABLE,
                          f"{DONATION_TABLE}[{key!r}] = {val} disagrees "
                          f"with repro-lint's DONATED_ARGNUMS mirror "
                          f"{want} — change both in the same PR")


def _scan_use_after_donate(mod, fn: FuncInfo, table) -> Iterable[Finding]:
    """Reads of a symbol after it went into a donated argnum of an AOT
    bucket program. Linearizes simple statements in source order — the
    sanctioned idiom rebinds every donated operand in the very assignment
    that makes the call."""
    if isinstance(fn.node, ast.Lambda):
        return
    prog_alias: dict[str, str] = {}   # local name -> donation-table key

    def sym(node):
        if isinstance(node, ast.Name):
            return node.id
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            return "self." + node.attr
        return None

    def donation_key(func):
        node = func.value if isinstance(func, ast.Subscript) else func
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self" and node.attr in table):
            return node.attr
        if isinstance(func, ast.Name):
            return prog_alias.get(func.id)
        return None

    def target_syms(targets):
        out, stack = set(), list(targets)
        while stack:
            t = stack.pop()
            if isinstance(t, (ast.Tuple, ast.List)):
                stack.extend(t.elts)
            elif isinstance(t, ast.Starred):
                stack.append(t.value)
            else:
                s = sym(t)
                if s is not None:
                    out.add(s)
        return out

    def track_alias(st):
        tgt = st.targets[0] if len(st.targets) == 1 else None
        if isinstance(tgt, ast.Name):
            pairs = [(tgt, st.value)]
        elif (isinstance(tgt, (ast.Tuple, ast.List))
              and isinstance(st.value, (ast.Tuple, ast.List))
              and len(tgt.elts) == len(st.value.elts)):
            pairs = list(zip(tgt.elts, st.value.elts))
        else:
            return
        for t, v in pairs:
            if not isinstance(t, ast.Name):
                continue
            node = v.value if isinstance(v, ast.Subscript) else v
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "self" and node.attr in table):
                prog_alias[t.id] = node.attr
            else:
                prog_alias.pop(t.id, None)

    dead: dict[str, int] = {}         # symbol -> line it was donated
    simple = sorted((st for st in ast.walk(fn.node)
                     if isinstance(st, (ast.Assign, ast.AugAssign,
                                        ast.AnnAssign, ast.Expr,
                                        ast.Return))),
                    key=lambda st: (st.lineno, st.col_offset))
    for st in simple:
        for node in ast.walk(st):      # 1. reads of dead symbols
            if (isinstance(node, (ast.Name, ast.Attribute))
                    and isinstance(getattr(node, "ctx", None), ast.Load)):
                s = sym(node)
                if s is not None and s in dead:
                    yield Finding(
                        mod.rel, node.lineno, R_DONATE, fn.qualname,
                        f"`{s}` was donated to an AOT program on line "
                        f"{dead.pop(s)} — its buffer is deleted; rebind "
                        f"it from the program's outputs before reading")
        donated = set()                # 2. donations made by this statement
        for call in (n for n in ast.walk(st) if isinstance(n, ast.Call)):
            key = donation_key(call.func)
            if key is None:
                continue
            for i in table[key]:
                if i < len(call.args):
                    s = sym(call.args[i])
                    if s is not None:
                        donated.add(s)
        rebound = set()                # 3. same-statement rebinds sanction
        if isinstance(st, ast.Assign):
            rebound = target_syms(st.targets)
            track_alias(st)
        elif isinstance(st, (ast.AugAssign, ast.AnnAssign)):
            rebound = target_syms([st.target])
        for s in rebound:
            dead.pop(s, None)
        dead.update({s: st.lineno for s in donated if s not in rebound})


def run(ctx: AnalysisContext) -> Iterable[Finding]:
    out: list[Finding] = []
    for mod in ctx.modules:
        aliases = alias_map(mod.tree)
        reachable = jit_reachable(mod.tree, aliases)
        for fn in reachable.values():
            out.extend(_scan_reachable(mod, fn, aliases))
        if "serving/" in mod.rel or mod.rel.startswith("serving"):
            reach_lines = {f.line for f in out if f.path == mod.rel}
            already: set = set()
            for fn in collect_functions(mod.tree):
                for f in _scan_serving(mod, fn, aliases, already):
                    if f.line not in reach_lines:
                        out.append(f)
            table, key_lines = _donation_table(mod.tree)
            out.extend(_scan_donation_drift(mod, table, key_lines))
            if table and mod.rel.endswith("serving/router_service.py"):
                out.extend(_scan_mirror(mod, table, key_lines))
            donate_table = table if table is not None else DONATED_ARGNUMS
            for fn in collect_functions(mod.tree):
                out.extend(_scan_use_after_donate(mod, fn, donate_table))
    return out
