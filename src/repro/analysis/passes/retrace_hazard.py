"""Pass 3 — retrace-hazard: per-call-varying host values hitting jit.

Two rules:

* ``retrace/jit-in-loop`` — constructing a compiled program
  (``jax.jit`` / ``pallas_call`` / ``shard_map`` / ``pmap``) inside a
  Python ``for``/``while`` body.  Each iteration builds a distinct
  callable with an empty cache, so nothing is ever reused.  (Calling an
  already-jitted function in a loop is fine; it's the *wrapping* in the
  loop that leaks.)

* ``retrace/varying-host-operand`` — a class whose method passes a
  *varying* instance attribute (one the class mutates with ``+= `` or a
  self-referential reassignment, e.g. a tick counter) as a bare operand
  into one of its jitted callables (attributes assigned from
  ``jax.jit(...)``).  Bare python ints retrace per value; the fix is the
  ``_tick32``-style wrap that converts to a device array *before* the
  call boundary, which this rule recognizes as any wrapping call on the
  operand path.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..engine import AnalysisContext, Finding
from ..jaxast import PROGRAM_BUILDERS, alias_map, resolves_to

R_LOOP = "retrace/jit-in-loop"
R_VARY = "retrace/varying-host-operand"


def _jit_in_loops(mod, aliases) -> Iterable[Finding]:
    class V(ast.NodeVisitor):
        def __init__(self):
            self.loop_depth = 0
            self.findings: list[Finding] = []

        def visit_For(self, node):
            self._loop(node)

        def visit_AsyncFor(self, node):
            self._loop(node)

        def visit_While(self, node):
            self._loop(node)

        def _loop(self, node):
            self.loop_depth += 1
            self.generic_visit(node)
            self.loop_depth -= 1

        def visit_Call(self, node):
            if self.loop_depth > 0:
                hit = resolves_to(node.func, aliases, PROGRAM_BUILDERS)
                if hit:
                    self.findings.append(Finding(
                        mod.rel, node.lineno, R_LOOP, "",
                        f"{hit.rsplit('.', 1)[-1]}(...) constructed inside "
                        "a python loop — every iteration compiles from "
                        "scratch; hoist the wrapper out of the loop"))
            self.generic_visit(node)

    v = V()
    v.visit(mod.tree)
    return v.findings


def _varying_attrs(cls: ast.ClassDef) -> set[str]:
    """Attrs the class mutates per call: ``self.x += ...`` or
    ``self.x = <expr mentioning self.x>``."""
    varying: set[str] = set()
    for node in ast.walk(cls):
        if (isinstance(node, ast.AugAssign)
                and isinstance(node.target, ast.Attribute)
                and isinstance(node.target.value, ast.Name)
                and node.target.value.id == "self"):
            varying.add(node.target.attr)
        elif isinstance(node, ast.Assign) and len(node.targets) == 1:
            t = node.targets[0]
            if (isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"):
                for ref in ast.walk(node.value):
                    if (isinstance(ref, ast.Attribute)
                            and isinstance(ref.value, ast.Name)
                            and ref.value.id == "self"
                            and ref.attr == t.attr
                            and not isinstance(node.value, ast.Call)):
                        varying.add(t.attr)
    return varying


def _jitted_attrs(cls: ast.ClassDef, aliases) -> set[str]:
    """Attrs bound to compiled callables: ``self.x = jax.jit(...)``."""
    jitted: set[str] = set()
    for node in ast.walk(cls):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Attribute)
                and isinstance(node.targets[0].value, ast.Name)
                and node.targets[0].value.id == "self"
                and isinstance(node.value, ast.Call)
                and resolves_to(node.value.func, aliases, PROGRAM_BUILDERS)):
            jitted.add(node.targets[0].attr)
    return jitted


def _bare_self_attrs(node: ast.AST) -> Iterable[ast.Attribute]:
    """self.X occurrences not wrapped by any call on the path from the
    operand root — a wrapping call (jnp.asarray, _tick32, ...) converts
    before the jit boundary and is the sanctioned pattern."""
    if isinstance(node, ast.Call):
        return
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        yield node
        return
    for child in ast.iter_child_nodes(node):
        yield from _bare_self_attrs(child)


def _varying_operands(mod, aliases) -> Iterable[Finding]:
    for cls in ast.walk(mod.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        varying = _varying_attrs(cls)
        jitted = _jitted_attrs(cls, aliases)
        if not varying or not jitted:
            continue
        for call in ast.walk(cls):
            if not (isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Attribute)
                    and isinstance(call.func.value, ast.Name)
                    and call.func.value.id == "self"
                    and call.func.attr in jitted):
                continue
            operands = list(call.args) + [kw.value for kw in call.keywords]
            for op in operands:
                for attr in _bare_self_attrs(op):
                    if attr.attr in varying:
                        yield Finding(
                            mod.rel, call.lineno, R_VARY, cls.name,
                            f"per-call-varying `self.{attr.attr}` passed "
                            f"bare into jitted `self.{call.func.attr}` — "
                            "retraces on every new value; wrap it in a "
                            "device array (see the _tick32 idiom) first")


def run(ctx: AnalysisContext) -> Iterable[Finding]:
    out: list[Finding] = []
    for mod in ctx.modules:
        aliases = alias_map(mod.tree)
        out.extend(_jit_in_loops(mod, aliases))
        out.extend(_varying_operands(mod, aliases))
    return out
