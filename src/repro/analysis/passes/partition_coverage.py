"""Pass 4 — partition-spec coverage for state-carrying pytree records.

The sharding contract (ROADMAP "standing architecture") centralizes every
PartitionSpec in ``sharding/routing_rules.py``.  The failure mode this
pass exists for: a field grows on a NamedTuple that rides policy/serving
state (often with a ``None`` default, so nothing crashes), while the spec
constructor in routing_rules silently keeps sharding the *old* record —
the new field gets replicated or mis-partitioned under the mesh.

Detection: every class defined in the scanned tree whose bases mention
``NamedTuple`` is indexed with its ordered field list.  Any *spec-shaped*
constructor call of such a class — all-keyword, every value built from
``P(...)`` / ``PartitionSpec(...)`` (``None`` allowed as an explicit
"replicate" marker) — must name **every** field of the class:

* ``partition/missing-field``  a class field absent from the call;
* ``partition/unknown-field``  a keyword that matches no class field
  (classic rename drift).

Ordinary data constructions of the same classes (positional args, array
values) are not spec-shaped and are ignored.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..engine import AnalysisContext, Finding
from ..jaxast import alias_map, dotted_name

R_MISSING = "partition/missing-field"
R_UNKNOWN = "partition/unknown-field"

SPEC_NAMES = {"P", "PartitionSpec", "NamedSharding"}


def _namedtuple_fields(ctx: AnalysisContext) -> dict[str, tuple[str, list[str]]]:
    """class name -> (defining module rel path, ordered field names)."""
    out: dict[str, tuple[str, list[str]]] = {}
    for mod in ctx.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            base_names = {dotted_name(b) or "" for b in node.bases}
            if not any(b.split(".")[-1] == "NamedTuple" for b in base_names):
                continue
            fields = [st.target.id for st in node.body
                      if isinstance(st, ast.AnnAssign)
                      and isinstance(st.target, ast.Name)]
            if fields:
                out[node.name] = (mod.rel, fields)
    return out


def _is_spec_value(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and node.value is None:
        return True   # explicit "replicated" marker
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        return bool(name) and name.split(".")[-1] in SPEC_NAMES
    if isinstance(node, ast.Name):
        # a P(...) bound to a local (e.g. batch_axis spec reused per field)
        return node.id.islower() and len(node.id) <= 12
    return False


def run(ctx: AnalysisContext) -> Iterable[Finding]:
    classes = _namedtuple_fields(ctx)
    out: list[Finding] = []
    for mod in ctx.modules:
        # enclosing function qualname for nicer symbols
        func_of: dict[ast.AST, str] = {}
        for fn in ast.walk(mod.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for sub in ast.walk(fn):
                    func_of.setdefault(sub, fn.name)
        for call in ast.walk(mod.tree):
            if not isinstance(call, ast.Call):
                continue
            name = dotted_name(call.func)
            cls = name.split(".")[-1] if name else None
            if cls not in classes:
                continue
            if call.args or not call.keywords:
                continue        # positional/data construction, not a spec map
            if any(kw.arg is None for kw in call.keywords):
                continue        # **kwargs — can't check statically
            # spec-shaped = at least one literal P(...) value anchors the
            # call, and nothing looks like array data
            anchored = any(
                isinstance(kw.value, ast.Call)
                and (dotted_name(kw.value.func) or "").split(".")[-1]
                in SPEC_NAMES
                for kw in call.keywords)
            if not anchored:
                continue        # ordinary data construction
            if not all(_is_spec_value(kw.value) for kw in call.keywords):
                continue        # mixed call — not a pure spec map
            _def_mod, fields = classes[cls]
            given = [kw.arg for kw in call.keywords]
            symbol = func_of.get(call, "")
            for f in fields:
                if f not in given:
                    out.append(Finding(
                        mod.rel, call.lineno, R_MISSING, symbol,
                        f"spec for {cls} misses field `{f}` — it will be "
                        "silently replicated/mis-sharded under the mesh; "
                        "add an explicit entry (None = replicate)"))
            for g in given:
                if g not in fields:
                    out.append(Finding(
                        mod.rel, call.lineno, R_UNKNOWN, symbol,
                        f"spec for {cls} names unknown field `{g}` — "
                        "stale after a rename?"))
    return out
