"""Pass registry for repro-lint.

Each pass is ``(name, callable(AnalysisContext) -> Iterable[Finding])``.
Order is cosmetic — findings are globally sorted by the engine.
"""
from . import (partition_coverage, prng, protocol_kernel, retrace_hazard,
               trace_hazard)

REGISTRY = [
    ("trace-hazard", trace_hazard.run),
    ("prng-hygiene", prng.run),
    ("retrace-hazard", retrace_hazard.run),
    ("partition-coverage", partition_coverage.run),
    ("protocol-kernel", protocol_kernel.run),
]

__all__ = ["REGISTRY"]
