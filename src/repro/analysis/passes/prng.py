"""Pass 2 — PRNG hygiene: linear use of jax PRNG keys.

A PRNG key is a linear resource: after it seeds one sampler or one
``split``, reusing the *same* key value silently correlates draws.  The
legitimate non-consuming reuse is ``jax.random.fold_in(key, data)`` —
deriving per-step subkeys from a base key.

Tracked keys: locals assigned from ``PRNGKey``/``key``/``split``/
``fold_in``/``clone`` calls, plus parameters literally named ``key`` or
``rng`` (names like ``k`` are too overloaded to taint).  Each *consuming*
occurrence (appearing anywhere except as ``fold_in``'s base argument)
bumps a use counter; the second consumption of the same name without an
intervening re-assignment is flagged as ``prng/key-reuse``.

Control flow: ``if``/``else`` branches fork the counter state and merge
with max (a use on either branch counts).  Loop bodies are processed
twice so a consumption that is fine once but repeats every iteration —
``for i in ...: sample(key)`` — trips on the second sweep.  ``for sub in
split(key, n)`` re-binds ``sub`` fresh each iteration and stays clean.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..engine import AnalysisContext, Finding
from ..jaxast import PRNG_SOURCES, alias_map, collect_functions, resolves_to

RULE = "prng/key-reuse"
KEY_PARAM_NAMES = {"key", "rng", "prng_key", "rng_key"}
FOLD_IN = {"jax.random.fold_in"}
SPLIT = {"jax.random.split"}


def _terminates(stmts: list) -> bool:
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


class _FuncScan:
    def __init__(self, mod, fn, aliases):
        self.mod = mod
        self.fn = fn
        self.aliases = aliases
        self.findings: list[Finding] = []
        self.emitted: set[tuple[str, int]] = set()

    # -- expression side: count consuming uses ------------------------------

    def _consume(self, name: str, line: int, state: dict[str, int]):
        if name not in state:
            return
        state[name] += 1
        if state[name] >= 2 and (name, line) not in self.emitted:
            self.emitted.add((name, line))
            self.findings.append(Finding(
                self.mod.rel, line, RULE, self.fn.qualname,
                f"key `{name}` consumed again without split/fold_in — "
                "draws will be correlated"))

    def _uses(self, node: ast.AST, state: dict[str, int]):
        """Walk an expression, counting consuming key occurrences."""
        if isinstance(node, ast.Call):
            if resolves_to(node.func, self.aliases, FOLD_IN) and node.args:
                # base key of fold_in is the blessed non-consuming reuse
                for extra in node.args[1:]:
                    self._uses(extra, state)
                for kw in node.keywords:
                    self._uses(kw.value, state)
                return
            for child in list(node.args) + [kw.value for kw in node.keywords]:
                self._uses(child, state)
            self._uses(node.func, state)
            return
        if isinstance(node, ast.Subscript) and isinstance(node.value,
                                                          ast.Name):
            # ks[i] picks one subkey out of a split batch — indices are
            # beyond a syntactic pass, so indexing never consumes the base.
            self._uses(node.slice, state)
            return
        if isinstance(node, ast.Name):
            self._consume(node.id, node.lineno, state)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return   # separate scope, scanned on its own
        for child in ast.iter_child_nodes(node):
            self._uses(child, state)

    # -- statement side ------------------------------------------------------

    def _assign_targets(self, targets, fresh: bool, state):
        for t in targets:
            if isinstance(t, (ast.Tuple, ast.List)):
                self._assign_targets(t.elts, fresh, state)
            elif isinstance(t, ast.Starred):
                self._assign_targets([t.value], fresh, state)
            elif isinstance(t, ast.Name):
                if fresh:
                    state[t.id] = 0
                else:
                    state.pop(t.id, None)
            # Attribute/Subscript targets (self._key = ...) are not tracked:
            # attribute lifetimes cross method boundaries.

    def block(self, stmts, state: dict[str, int]):
        for st in stmts:
            self.stmt(st, state)

    def stmt(self, st: ast.stmt, state: dict[str, int]):
        if isinstance(st, ast.If):
            self._uses(st.test, state)
            s1, s2 = dict(state), dict(state)
            self.block(st.body, s1)
            self.block(st.orelse, s2)
            # A branch that leaves the function (early return/raise) never
            # reaches the fall-through code: its counts stay out of the
            # merge (uses *inside* it were already checked above).
            live = []
            if not _terminates(st.body):
                live.append(s1)
            if not _terminates(st.orelse):
                live.append(s2)
            if not live:
                live = [s2]    # both exit; fall-through is unreachable
            for n in set().union(*(set(s) for s in live)):
                state[n] = max(s.get(n, 0) for s in live)
        elif isinstance(st, (ast.For, ast.AsyncFor)):
            self._uses(st.iter, state)
            iter_is_split = (isinstance(st.iter, ast.Call) and
                             resolves_to(st.iter.func, self.aliases, SPLIT))
            for _sweep in range(2):
                # the loop target re-binds every iteration (fresh subkey
                # when iterating a split batch, untracked otherwise)
                self._assign_targets(
                    [st.target], fresh=bool(iter_is_split), state=state)
                self.block(st.body, state)
            self.block(st.orelse, state)
        elif isinstance(st, ast.While):
            for _sweep in range(2):
                self._uses(st.test, state)
                self.block(st.body, state)
            self.block(st.orelse, state)
        elif isinstance(st, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = st.value
            if value is None:
                return
            targets = (st.targets if isinstance(st, ast.Assign)
                       else [st.target])
            fresh = (isinstance(value, ast.Call)
                     and resolves_to(value.func, self.aliases, PRNG_SOURCES))
            self._uses(value, state)
            self._assign_targets(targets, fresh=bool(fresh), state=state)
        elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return   # nested scope scanned separately
        elif isinstance(st, (ast.With, ast.AsyncWith)):
            for item in st.items:
                self._uses(item.context_expr, state)
            self.block(st.body, state)
        elif isinstance(st, ast.Try):
            self.block(st.body, state)
            for h in st.handlers:
                self.block(h.body, state)
            self.block(st.orelse, state)
            self.block(st.finalbody, state)
        else:
            for child in ast.iter_child_nodes(st):
                if isinstance(child, ast.expr):
                    self._uses(child, state)
                elif isinstance(child, ast.stmt):
                    self.stmt(child, state)


def run(ctx: AnalysisContext) -> Iterable[Finding]:
    out: list[Finding] = []
    for mod in ctx.modules:
        aliases = alias_map(mod.tree)
        for fn in collect_functions(mod.tree):
            scan = _FuncScan(mod, fn, aliases)
            state = {p: 0 for p in fn.pos_params + sorted(fn.kwonly)
                     if p in KEY_PARAM_NAMES}
            scan.block(fn.node.body, state)
            out.extend(scan.findings)
    return out
