"""repro-lint: JAX-aware static analysis for the routing reproduction.

Stdlib-only (``ast`` + ``json``) so the CLI runs in environments without
jax installed — the CI ``analysis`` lane deliberately skips the heavy
requirements.  The runtime helper :mod:`repro.analysis.retrace` is the one
submodule that touches live jitted callables; it is imported lazily so
``python -m repro.analysis`` never pulls it in.

Layout
------
``engine``          Finding dataclass, module loader, baseline matching.
``jaxast``          Alias resolution + jit-reachability approximation.
``passes``          The five registered passes (see ``passes.REGISTRY``).
``retrace``         Runtime ``assert_flat`` context manager (needs jax).
"""
from .engine import AnalysisContext, Finding, load_modules, run_passes

__all__ = ["AnalysisContext", "Finding", "load_modules", "run_passes"]
