"""JAX-aware AST helpers shared by the repro-lint passes.

The central approximation is *jit-reachability*: a per-module fixpoint over
which function definitions can end up inside a jax trace.  Entry points are
functions decorated with (or passed to) any of the tracing transforms in
``JIT_WRAPPERS``; the closure adds nested ``def``s and same-module callees
reached by bare-name or ``self.``-method calls.  This is deliberately
module-local — cross-module call graphs buy little here because every
tracing boundary in this repo is declared next to the traced function —
and errs toward over-approximation, which is the right direction for a
lint that feeds a baseline file.
"""
from __future__ import annotations

import ast
import dataclasses

# Dotted names whose callees/decorated functions run under a jax trace.
JIT_WRAPPERS = {
    "jax.jit", "jax.pmap", "jax.vmap", "jax.grad", "jax.value_and_grad",
    "jax.jacfwd", "jax.jacrev", "jax.hessian", "jax.linearize",
    "jax.checkpoint", "jax.remat", "jax.custom_vjp", "jax.custom_jvp",
    "jax.lax.scan", "jax.lax.cond", "jax.lax.switch", "jax.lax.while_loop",
    "jax.lax.fori_loop", "jax.lax.map", "jax.lax.associative_scan",
    "jax.experimental.pallas.pallas_call",
    "jax.experimental.shard_map.shard_map",
}

# The subset that memoizes compiled programs keyed on operand structure —
# calling these inside a Python loop is the classic retrace smell.
PROGRAM_BUILDERS = {
    "jax.jit", "jax.pmap",
    "jax.experimental.pallas.pallas_call",
    "jax.experimental.shard_map.shard_map",
}

PRNG_SOURCES = {
    "jax.random.PRNGKey", "jax.random.key", "jax.random.split",
    "jax.random.fold_in", "jax.random.clone", "jax.random.wrap_key_data",
}


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for Name/Attribute chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def alias_map(tree: ast.Module) -> dict[str, str]:
    """Local name -> canonical dotted path, from every import statement."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            prefix = "." * node.level + mod
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = f"{prefix}.{a.name}"
    return out


def resolve(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Canonical dotted path of a Name/Attribute, through import aliases."""
    name = dotted_name(node)
    if name is None:
        return None
    head, _, rest = name.partition(".")
    if head in aliases:
        name = aliases[head] + ("." + rest if rest else "")
    return name


def resolves_to(node: ast.AST, aliases: dict[str, str],
                targets: set[str]) -> str | None:
    r = resolve(node, aliases)
    if r is None:
        return None
    if r in targets:
        return r
    # Unaliased tail paths (`shard_map(...)` imported without going through
    # an import statement we saw, e.g. re-exported names): suffix match.
    for t in targets:
        if t.endswith("." + r):
            return t
    return None


@dataclasses.dataclass
class FuncInfo:
    node: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda
    qualname: str
    pos_params: list[str]       # positional (incl. pos-only) arg names
    kwonly: set[str]
    in_class: str | None        # enclosing class name, if a method


def collect_functions(tree: ast.Module) -> list[FuncInfo]:
    out: list[FuncInfo] = []

    def visit(node: ast.AST, stack: list[str], cls: str | None):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = ".".join(stack + [child.name])
                a = child.args
                pos = [p.arg for p in a.posonlyargs + a.args]
                out.append(FuncInfo(child, qn, pos,
                                    {p.arg for p in a.kwonlyargs}, cls))
                visit(child, stack + [child.name], cls)
            elif isinstance(child, ast.ClassDef):
                visit(child, stack + [child.name], child.name)
            else:
                visit(child, stack, cls)

    visit(tree, [], None)
    return out


def _callable_refs(node: ast.AST, aliases: dict[str, str]) -> list[str]:
    """Names that a jit-wrapper argument might bind to a local def."""
    if isinstance(node, ast.Name):
        return [node.id]
    if isinstance(node, ast.Attribute):
        # self.method or module.fn — keep the final attribute for matching.
        return [node.attr]
    if isinstance(node, ast.Call):
        if resolves_to(node.func, aliases, {"functools.partial"}) and node.args:
            return _callable_refs(node.args[0], aliases)
    if isinstance(node, ast.Lambda):
        return []   # handled by the caller via the node itself
    return []


def jit_reachable(tree: ast.Module,
                  aliases: dict[str, str]) -> dict[ast.AST, FuncInfo]:
    """Approximate the set of function defs that can run under a trace."""
    funcs = collect_functions(tree)
    by_name: dict[str, list[FuncInfo]] = {}
    for f in funcs:
        by_name.setdefault(f.node.name, []).append(f)

    entries: set[ast.AST] = set()
    for f in funcs:
        for dec in f.node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if resolves_to(target, aliases, JIT_WRAPPERS):
                entries.add(f.node)
            elif (isinstance(dec, ast.Call)
                  and resolves_to(dec.func, aliases, {"functools.partial"})
                  and dec.args
                  and resolves_to(dec.args[0], aliases, JIT_WRAPPERS)):
                entries.add(f.node)

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        is_wrapper = resolves_to(node.func, aliases, JIT_WRAPPERS)
        is_defvjp = (isinstance(node.func, ast.Attribute)
                     and node.func.attr in ("defvjp", "defjvp", "def_fwd",
                                            "def_bwd"))
        if not (is_wrapper or is_defvjp):
            continue
        operands = list(node.args) + [kw.value for kw in node.keywords]
        for op in operands:
            if isinstance(op, ast.Lambda):
                entries.add(op)
                continue
            for ref in _callable_refs(op, aliases):
                for f in by_name.get(ref, []):
                    entries.add(f.node)

    # Fixpoint: nested defs + same-module callees of reachable functions.
    info = {f.node: f for f in funcs}
    reachable = {n for n in entries if n in info}
    frontier = list(reachable)
    while frontier:
        fn = frontier.pop()
        for node in ast.walk(fn):
            name = None
            if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node is not fn and node in info):
                name = node.name   # nested def: conservatively reachable
                cands = [info[node]]
            elif isinstance(node, ast.Call):
                if isinstance(node.func, ast.Name):
                    name = node.func.id
                elif (isinstance(node.func, ast.Attribute)
                      and isinstance(node.func.value, ast.Name)
                      and node.func.value.id == "self"):
                    name = node.func.attr
                cands = by_name.get(name, []) if name else []
            else:
                continue
            for c in cands:
                if c.node not in reachable:
                    reachable.add(c.node)
                    frontier.append(c.node)
    return {n: info[n] for n in reachable if n in info}


def module_int_constants(tree: ast.Module) -> dict[str, int]:
    """Top-level ``NAME = <int literal>`` assignments."""
    out: dict[str, int] = {}
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, int)
                and not isinstance(node.value.value, bool)):
            out[node.targets[0].id] = node.value.value
    return out


def contains_call(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Call) for n in ast.walk(node))
