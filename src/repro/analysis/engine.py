"""Core machinery of repro-lint: findings, module loading, baselines.

A *pass* is a callable ``(AnalysisContext) -> Iterable[Finding]``.  The
engine parses every ``.py`` file under the requested roots once, hands the
shared context to each pass, and normalizes the output: findings are
deduplicated, sorted, and split against the committed suppression file
(``analysis/baseline.json``) so ``--fail-on-new`` only trips on findings
that are not already acknowledged with a reason.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
from typing import Callable, Iterable, Sequence

SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "analysis_fixtures"}


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One diagnostic: ``path:line: [rule] symbol: message``."""

    path: str          # repo-relative, posix separators
    line: int
    rule: str          # e.g. "trace-hazard/host-sync"
    symbol: str        # enclosing function/class qualname ("" at module level)
    message: str

    def format(self) -> str:
        where = f"{self.symbol}: " if self.symbol else ""
        return f"{self.path}:{self.line}: [{self.rule}] {where}{self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Module:
    """A parsed source file plus the names passes key off."""

    path: pathlib.Path     # absolute
    rel: str               # repo-relative posix path (finding.path)
    qualname: str          # dotted module name, e.g. "repro.kernels.ops"
    tree: ast.Module
    source: str


@dataclasses.dataclass
class AnalysisContext:
    root: pathlib.Path
    modules: list[Module]

    def by_qualname(self, qualname: str) -> Module | None:
        for m in self.modules:
            if m.qualname == qualname:
                return m
        return None


def _qualname_for(rel: str) -> str:
    parts = pathlib.PurePosixPath(rel).with_suffix("").parts
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def load_modules(paths: Sequence[pathlib.Path],
                 root: pathlib.Path) -> AnalysisContext:
    """Parse every .py under ``paths`` (files or directories)."""
    root = root.resolve()
    files: list[pathlib.Path] = []
    for p in paths:
        p = p.resolve()
        if p.is_dir():
            files.extend(sorted(
                f for f in p.rglob("*.py")
                if not any(part in SKIP_DIRS for part in f.parts)))
        elif p.suffix == ".py":
            files.append(p)
    modules = []
    for f in files:
        try:
            rel = f.relative_to(root).as_posix()
        except ValueError:
            rel = f.name
        source = f.read_text()
        try:
            tree = ast.parse(source, filename=str(f))
        except SyntaxError as e:   # surfaced as a finding, not a crash
            modules.append(Module(f, rel, _qualname_for(rel),
                                  ast.Module(body=[], type_ignores=[]),
                                  source))
            modules[-1].tree._repro_syntax_error = e  # type: ignore[attr-defined]
            continue
        modules.append(Module(f, rel, _qualname_for(rel), tree, source))
    return AnalysisContext(root=root, modules=modules)


def run_passes(ctx: AnalysisContext,
               passes: Iterable[tuple[str, Callable]]) -> list[Finding]:
    findings: set[Finding] = set()
    for m in ctx.modules:
        err = getattr(m.tree, "_repro_syntax_error", None)
        if err is not None:
            findings.add(Finding(m.rel, err.lineno or 1, "engine/syntax-error",
                                 "", f"file does not parse: {err.msg}"))
    for _name, fn in passes:
        findings.update(fn(ctx))
    return sorted(findings)


# ---------------------------------------------------------------------------
# Baseline suppression
# ---------------------------------------------------------------------------
#
# analysis/baseline.json holds a list of entries:
#   {"rule": ..., "path": ..., "symbol": ... (optional),
#    "contains": ... (optional substring of message), "reason": ...}
# "reason" is mandatory — a suppression without a why is a bug magnet.
# Lines are deliberately NOT part of the match key so routine edits above a
# baselined finding don't invalidate the entry.

def load_baseline(path: pathlib.Path) -> list[dict]:
    if not path.exists():
        return []
    entries = json.loads(path.read_text())
    if not isinstance(entries, list):
        raise ValueError(f"{path}: baseline must be a JSON list")
    for e in entries:
        for req in ("rule", "path", "reason"):
            if not e.get(req):
                raise ValueError(
                    f"{path}: baseline entry {e!r} missing required "
                    f"'{req}' field")
    return entries


def entry_matches(entry: dict, f: Finding) -> bool:
    if entry["rule"] != f.rule or entry["path"] != f.path:
        return False
    if entry.get("symbol") is not None and entry["symbol"] != f.symbol:
        return False
    if entry.get("contains") and entry["contains"] not in f.message:
        return False
    return True


def split_against_baseline(
        findings: Sequence[Finding], entries: Sequence[dict],
) -> tuple[list[Finding], list[Finding], list[dict]]:
    """Return (new, suppressed, unused_entries)."""
    used = [False] * len(entries)
    new, suppressed = [], []
    for f in findings:
        hit = False
        for i, e in enumerate(entries):
            if entry_matches(e, f):
                used[i] = True
                hit = True
        (suppressed if hit else new).append(f)
    unused = [e for i, e in enumerate(entries) if not used[i]]
    return new, suppressed, unused
