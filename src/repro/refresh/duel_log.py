"""Exportable duel-log ring — the data side of the online representation loop.

The serving layer's replay ring (``fgts.FGTSState``) exists to train the
*posterior* and therefore stores exactly what the likelihood needs. The
refresh loop needs more: to re-run CCFT on live traffic and causally
calibrate it against the router's own selection bias, every logged duel must
carry the query features, the routed pair, the outcome, the preference it
was served under, the act-time selection propensity, and (when known) the
query's category. ``DuelLog`` is a fixed-capacity ring of exactly that
tuple, folded inside the jitted feedback programs (single masked scatter per
field, the ``fgts.observe_batch`` idiom — zero new syncs on the serving
path) and exported wholesale to the host for the offline refresh job.

Capacity must be a power of two: the write head is ``count % capacity`` on a
wrapping int32 counter, and only a power-of-two capacity keeps slot
addressing consistent across the 2^31 wrap (same contract as the pending
ring and the replay ring).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DuelLog(NamedTuple):
    """Ring of resolved live duels with their causal-logging companions."""
    x: jax.Array          # (C, d) float32 — query features
    a1: jax.Array         # (C,)  int32   — routed pair
    a2: jax.Array         # (C,)  int32
    y: jax.Array          # (C,)  float32 — preference outcome (+1/-1)
    pref: jax.Array       # (C,)  float32 — per-duel preference weight
    prop: jax.Array       # (C,)  float32 — act-time pair propensity
    cat: jax.Array        # (C,)  int32   — query category (-1 = unknown)
    issued_at: jax.Array  # (C,)  int32   — service tick the duel was issued
    valid: jax.Array      # (C,)  bool    — slot holds a folded duel
    count: jax.Array      # ()    int32   — duels folded so far (write head)


def init_log(capacity: int, dim: int) -> DuelLog:
    """Empty log. ``capacity`` must be a power of two (wrapping int32 write
    head, same contract as ``feedback_queue.init_pending``)."""
    if capacity < 1 or capacity & (capacity - 1):
        raise ValueError(
            f"DuelLog capacity must be a power of two (slot = count % "
            f"capacity on a wrapping int32 counter); got {capacity} — "
            f"round up with feedback_queue.next_pow2")
    z = jnp.zeros
    return DuelLog(
        x=z((capacity, dim), jnp.float32),
        a1=z((capacity,), jnp.int32),
        a2=z((capacity,), jnp.int32),
        y=z((capacity,), jnp.float32),
        pref=z((capacity,), jnp.float32),
        prop=jnp.ones((capacity,), jnp.float32),
        cat=jnp.full((capacity,), -1, jnp.int32),
        issued_at=z((capacity,), jnp.int32),
        valid=z((capacity,), bool),
        count=z((), jnp.int32),
    )


def fold(log: DuelLog, x: jax.Array, a1: jax.Array, a2: jax.Array,
         y: jax.Array, pref: jax.Array, prop: jax.Array, cat: jax.Array,
         issued_at: jax.Array, mask: jax.Array) -> DuelLog:
    """Masked single-scatter append of a resolved batch (shape-static).

    Rows where ``mask`` is False (stale votes, bucket padding) are never
    written — kept row i lands at slot ``(count + rank_i) % C`` with rank
    counted over kept rows only, so the result is bit-identical to
    compacting first and appending sequentially (the ``fgts.observe_batch``
    idiom, including the keep-last-C overflow rule that also keeps scatter
    indices unique). Pure pytree code: it jits, shards and donates exactly
    like the pending ring next to it.
    """
    cap = log.x.shape[0]
    mask = mask.astype(bool)
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    n = jnp.sum(mask, dtype=log.count.dtype)
    write = mask & (rank >= n - cap)          # over-capacity: keep last C
    idx = jnp.where(write, (log.count + rank) % cap, cap)   # cap = OOB, drop
    return DuelLog(
        x=log.x.at[idx].set(x, mode="drop"),
        a1=log.a1.at[idx].set(a1.astype(jnp.int32), mode="drop"),
        a2=log.a2.at[idx].set(a2.astype(jnp.int32), mode="drop"),
        y=log.y.at[idx].set(y.astype(jnp.float32), mode="drop"),
        pref=log.pref.at[idx].set(pref.astype(jnp.float32), mode="drop"),
        prop=log.prop.at[idx].set(prop.astype(jnp.float32), mode="drop"),
        cat=log.cat.at[idx].set(cat.astype(jnp.int32), mode="drop"),
        issued_at=log.issued_at.at[idx].set(issued_at.astype(jnp.int32),
                                            mode="drop"),
        valid=log.valid.at[idx].set(True, mode="drop"),
        count=log.count + n,
    )


def export(log: DuelLog) -> dict:
    """Device -> host export of the logged duels for the offline refresh job.

    One deliberate ``jax.device_get`` of the whole ring (refresh cadence is
    hundreds-of-rounds, so this sync is off the serving hot path by
    construction); returns only the valid rows as numpy arrays.
    """
    import numpy as np
    host = jax.device_get(log)
    keep = np.asarray(host.valid, bool)
    return {
        "x": np.asarray(host.x)[keep],
        "a1": np.asarray(host.a1)[keep],
        "a2": np.asarray(host.a2)[keep],
        "y": np.asarray(host.y)[keep],
        "pref": np.asarray(host.pref)[keep],
        "prop": np.asarray(host.prop)[keep],
        "cat": np.asarray(host.cat)[keep],
        "issued_at": np.asarray(host.issued_at)[keep],
        "count": int(host.count),
    }
