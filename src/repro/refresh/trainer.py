"""The offline half of the online representation loop: causal CCFT refresh.

Given an exported ``DuelLog`` (see ``refresh.duel_log``) and the offline
corpus CCFT was originally fine-tuned on, ``refresh_table`` re-runs the
paper's representation pipeline against *live* evidence:

1. **Encoder refresh** — ``contrastive.finetune_categorical`` on the offline
   corpus, with anchor sampling re-weighted to the live traffic's category
   mix (``row_weights``): categories the deployment actually sees get
   proportionally more contrastive signal.
2. **Causal duel scores** — per-(arm, category) win rates from the logged
   duels, inverse-propensity-weighted per "Causal LLM Routing: End-to-End
   Regret Minimization from Observational Data" (PAPERS.md): a win logged
   under propensity p counts 1/p, so arms the logging policy under-served
   are not spuriously scored down by their own scarcity. ``causal=False``
   is the naive estimator (the bench's ablation on deliberately biased
   logs). Propensities are clipped at ``prop_floor`` for variance control.
3. **Table rebuild** — ``ccft.model_embeddings`` on the refreshed category
   embeddings and duel scores, through any of the paper's four weighting
   variants — an offline job emitting a refreshed (K_max, d) table for
   ``RouterService.apply_table`` / ``model_pool.set_table``.

Everything here runs *off* the serving path (host-side, minutes-scale
cadence); the only serving-side artifacts are the jitted log fold and the
jitted table swap, both retrace-free.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ccft
from repro.core.model_pool import ModelPool


@dataclasses.dataclass(frozen=True)
class RefreshConfig:
    """Knobs of the standing refresh cycle.

    ``every`` is the service-side cadence: ``RouterService.refresh_due()``
    turns True once that many duels have been folded into the log since the
    last ``apply_table`` (0 = manual refreshes only). ``capacity`` sizes
    the duel-log ring (rounded up to a power of two by the service).
    ``causal`` is the calibration knob: True inverse-propensity-weights
    logged outcomes, False is the naive estimator.
    """
    every: int = 0
    capacity: int = 1024
    n_categories: int = 8
    weighting: str = "excel_perf_cost"   # one of ccft.WEIGHTINGS
    tau: int = 3
    causal: bool = True
    prop_floor: float = 0.05             # IPW clip: w = 1 / max(p, floor)
    lam: float = 0.05                    # perf-cost blend for *_cost variants
    epochs: int = 2
    steps_per_epoch: int = 20
    batch: int = 64
    lr: float = 1e-3
    reseed: bool = False                 # re-warm-start posterior after swap

    def __post_init__(self):
        if self.weighting not in ccft.WEIGHTINGS:
            raise ValueError(f"refresh weighting {self.weighting!r} not in "
                             f"{ccft.WEIGHTINGS}")
        if self.capacity < 1:
            raise ValueError(f"refresh capacity must be >= 1, "
                             f"got {self.capacity}")
        if not 0.0 < self.prop_floor <= 1.0:
            raise ValueError(f"prop_floor must be in (0, 1], "
                             f"got {self.prop_floor}")


def category_mix(cat, n_categories: int):
    """(M,) live-traffic category weights from logged labels (-1 = unknown
    rows are ignored; an empty/unlabelled log degrades to uniform)."""
    cat = jnp.asarray(cat, jnp.int32)
    known = (cat >= 0) & (cat < n_categories)
    counts = jnp.zeros((n_categories,), jnp.float32).at[
        jnp.where(known, cat, n_categories)].add(1.0, mode="drop")
    return jnp.where(jnp.sum(counts) > 0, counts,
                     jnp.ones((n_categories,), jnp.float32))


def assign_categories(x, xi):
    """Nearest-category-prototype labels for unlabelled log rows.

    x: (N, d) query features; xi: (d, M) category embeddings. Cosine
    argmax — the same geometry the router scores with.
    """
    xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    cn = xi / jnp.maximum(jnp.linalg.norm(xi, axis=0, keepdims=True), 1e-12)
    return jnp.argmax(xn @ cn, axis=-1).astype(jnp.int32)


def duel_scores(a1, a2, y, cat, prop, k_max: int, n_categories: int, *,
                causal: bool = True, prop_floor: float = 0.05,
                smoothing: float = 1.0):
    """(K_max, M) per-(arm, category) duel win rates from logged outcomes.

    Each duel contributes one Bernoulli observation to both arms in its
    category column (a1 wins on y > 0, ties split); under ``causal`` each
    observation is weighted by 1 / max(propensity, floor), the standard
    IPW correction for the logging policy's selection bias. Laplace
    smoothing pulls unseen (arm, category) cells to 0.5 instead of 0 so a
    never-duelled arm is "unknown", not "bad". Rows with an out-of-range
    category are dropped.
    """
    a1 = jnp.asarray(a1, jnp.int32)
    a2 = jnp.asarray(a2, jnp.int32)
    y = jnp.asarray(y, jnp.float32)
    cat = jnp.asarray(cat, jnp.int32)
    w = 1.0 / jnp.clip(jnp.asarray(prop, jnp.float32), prop_floor, 1.0) \
        if causal else jnp.ones(y.shape, jnp.float32)
    ok = (cat >= 0) & (cat < n_categories)
    col = jnp.where(ok, cat, n_categories)         # OOB -> dropped scatter
    w = jnp.where(ok, w, 0.0)
    win1 = jnp.where(y > 0, 1.0, jnp.where(y < 0, 0.0, 0.5))
    wins = jnp.zeros((k_max, n_categories + 1), jnp.float32)
    wins = wins.at[a1, col].add(w * win1, mode="drop")
    wins = wins.at[a2, col].add(w * (1.0 - win1), mode="drop")
    plays = jnp.zeros((k_max, n_categories + 1), jnp.float32)
    plays = plays.at[a1, col].add(w, mode="drop")
    plays = plays.at[a2, col].add(w, mode="drop")
    wins, plays = wins[:, :n_categories], plays[:, :n_categories]
    return (wins + 0.5 * smoothing) / (plays + smoothing)


def refresh_table(key, log_data: dict, enc_params, enc_cfg, offline,
                  cfg: RefreshConfig, k_max: int,
                  costs=None) -> tuple[jax.Array, dict]:
    """One refresh cycle: logged duels -> refreshed (K_max, d) table.

    ``log_data`` is a ``duel_log.export`` dict (host arrays); ``offline``
    is the (tokens, mask, cats) corpus CCFT originally fine-tuned on;
    ``enc_params`` the encoder to refresh from. ``costs`` (K_max,) switches
    the *_cost weighting variants to the paper's perf - lam*cost blend.
    Returns (table, info) where info carries the refreshed encoder params,
    per-epoch losses, the live category mix and the duel-score matrix.
    """
    from repro.contrastive import finetune_categorical
    from repro.encoder.model import encode

    tokens, mask, cats = offline
    m = cfg.n_categories
    mix = category_mix(log_data["cat"], m)
    row_w = mix[jnp.asarray(cats, jnp.int32)]      # live-mix anchor weights
    params, losses = finetune_categorical(
        key, enc_params, tokens, mask, cats, enc_cfg, epochs=cfg.epochs,
        steps_per_epoch=cfg.steps_per_epoch, batch=cfg.batch, lr=cfg.lr,
        row_weights=row_w)
    emb = encode(params, tokens, mask, enc_cfg)
    xi = ccft.category_embeddings(emb, jnp.asarray(cats, jnp.int32), m)

    cat = jnp.asarray(log_data["cat"], jnp.int32)
    if cat.shape[0]:
        inferred = assign_categories(jnp.asarray(log_data["x"]), xi)
        cat = jnp.where(cat >= 0, cat, inferred)
    scores = duel_scores(log_data["a1"], log_data["a2"], log_data["y"], cat,
                         log_data["prop"], k_max, m, causal=cfg.causal,
                         prop_floor=cfg.prop_floor)
    if costs is not None and cfg.weighting.endswith("cost"):
        scores = ccft.perf_cost_scores(
            scores, jnp.asarray(costs, jnp.float32)[:, None], cfg.lam)
    table = ccft.model_embeddings(xi, scores, cfg.weighting, tau=cfg.tau)
    return table, dict(params=params, losses=losses, mix=mix, scores=scores,
                       n_duels=int(log_data["x"].shape[0]))


# ---------------------------------------------------------------------------
# Refresh schedules for the env loop (precomputed tables, in-scan swaps)
# ---------------------------------------------------------------------------

class RefreshSchedule(NamedTuple):
    """E table swaps replayed inside ``env.run``'s lax.scan: at scan step
    ``step[e]`` the pool's whole embedding table becomes ``table[e]``.
    Shape-static (misses are where'd away), mirroring ``PoolSchedule``."""
    step: jax.Array     # (E,) int32
    table: jax.Array    # (E, K_max, d) float32


def schedule(events) -> RefreshSchedule:
    """Build a RefreshSchedule from host (step, table) tuples."""
    steps = [int(s) for s, _ in events]
    tables = [jnp.asarray(t, jnp.float32) for _, t in events]
    return RefreshSchedule(step=jnp.asarray(steps, jnp.int32),
                           table=jnp.stack(tables))


def apply_refresh(pool: ModelPool, sched: RefreshSchedule, s) -> ModelPool:
    """Fold the table swap due at scan step ``s`` into the pool (at most one
    event per step; none = the pool rides through bit-unchanged)."""
    hit = sched.step == jnp.asarray(s, sched.step.dtype)          # (E,)
    n_hit = jnp.sum(hit, dtype=jnp.int32)
    mixed = jnp.einsum("e,ekd->kd", hit.astype(sched.table.dtype),
                       sched.table)
    return pool._replace(
        a_emb=jnp.where(n_hit > 0, mixed, pool.a_emb),
        generation=pool.generation + n_hit,
    )
