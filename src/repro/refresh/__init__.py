"""Online representation loop: duel logging, causal CCFT refresh, table swap.

``duel_log`` is the serving-side data capture (jitted ring fold, host
export); ``trainer`` is the offline job (IPW duel scores -> CCFT weighting
-> refreshed (K_max, d) table) plus the precomputed ``RefreshSchedule`` for
``env.run``. The hot swap itself lives in ``core.model_pool.set_table`` and
``serving.RouterService.apply_table``.
"""
from repro.refresh.duel_log import DuelLog, init_log, fold, export
from repro.refresh.trainer import (RefreshConfig, RefreshSchedule,
                                   apply_refresh, assign_categories,
                                   category_mix, duel_scores, refresh_table,
                                   schedule)

__all__ = [
    "DuelLog", "init_log", "fold", "export",
    "RefreshConfig", "RefreshSchedule", "apply_refresh", "assign_categories",
    "category_mix", "duel_scores", "refresh_table", "schedule",
]
